"""Scheduler tests: Alg. 3 placement, Eq. 6 steal gating, Table II-style
imbalance reduction, live pool execution, plan-cache behaviour, and the
chunk-schedule policy engine (Eq. 7 per-hop argmin)."""
import statistics
import threading
import time

import numpy as np
import pytest

from repro.core.plan import PlanCache, plan_key
from repro.core.scheduler import (CostModel, ScheduleSimulator, TaskSpec,
                                  WorkStealingPool, choose_chunk_schedule,
                                  hop_phase_time, phase_time, place_tasks)


def imbalanced_tasks(n_workers=6, per_worker=4, heavy=2.2, light=0.5,
                     heavy_workers=(0, 1)):
    tasks = []
    for w in range(n_workers):
        c = heavy if w in heavy_workers else light
        tasks.extend(TaskSpec(home=w, cost=c, data_bytes=32 << 20)
                     for _ in range(per_worker))
    return tasks


def test_simulator_deterministic():
    tasks = imbalanced_tasks()
    r1 = ScheduleSimulator(6, steal=True).run(tasks)
    r2 = ScheduleSimulator(6, steal=True).run(tasks)
    assert r1 == r2


def test_stealing_reduces_imbalance_and_walltime():
    """The Table II experiment: stealing must cut imbalance and wall time."""
    tasks = imbalanced_tasks()
    off = ScheduleSimulator(6, steal=False).run(tasks)
    on = ScheduleSimulator(6, steal=True).run(tasks)
    assert on["wall_s"] < off["wall_s"]
    assert on["imbalance_pct"] < off["imbalance_pct"] / 2
    assert on["steals"] > 0
    assert off["steals"] == 0


def test_steal_gate_eq6():
    """With steal cost above any predicted idle time, no steals happen."""
    tasks = imbalanced_tasks()
    cm = CostModel(steal_overhead_s=1e9)  # tau_s >> any idle
    r = ScheduleSimulator(6, steal=True, cost_model=cm).run(tasks)
    assert r["steals"] == 0


def test_heterogeneous_workers():
    """Slow workers keep their queues; fast ones absorb extra work."""
    tasks = [TaskSpec(home=w % 4, cost=1.0) for w in range(16)]
    fast = ScheduleSimulator(4, steal=True,
                             speeds=[4.0, 1.0, 1.0, 1.0]).run(tasks)
    flat = ScheduleSimulator(4, steal=True).run(tasks)
    assert fast["wall_s"] < flat["wall_s"]


def test_place_tasks_affinity_default():
    tasks = [TaskSpec(home=w, cost=1.0) for w in range(8)]
    sigma = place_tasks(tasks, 8)
    assert sigma == list(range(8))  # data-local placement


def test_place_tasks_rebalances_variance():
    # all tasks homed on worker 0 -> rebalance must spread them
    tasks = [TaskSpec(home=0, cost=1.0) for _ in range(16)]
    sigma = place_tasks(tasks, 4, variance_threshold=0.25)
    loads = [sigma.count(w) for w in range(4)]
    assert max(loads) < 16  # moved something off worker 0
    r_re = ScheduleSimulator(4, steal=False).run(tasks, sigma)
    r_naive = ScheduleSimulator(4, steal=False).run(tasks)
    assert r_re["wall_s"] < r_naive["wall_s"]


def _placement_loads(tasks, sigma, n_workers, cm=CostModel()):
    loads = [0.0] * n_workers
    for i, t in enumerate(tasks):
        loads[sigma[i]] += cm.placement_cost(t, sigma[i])
    return loads


def test_place_tasks_rebalance_scans_beyond_oversized_tail():
    """Regression (fails at HEAD): the rebalance pass popped only the tail
    of the most-loaded queue and gave up when migrating it would not help —
    even though cheaper tasks earlier in that queue would bring the
    coefficient of variation under the threshold."""
    tasks = ([TaskSpec(home=0, cost=1.0) for _ in range(2)]
             + [TaskSpec(home=0, cost=5.0)]        # oversized tail task
             + [TaskSpec(home=1, cost=2.5)])
    sigma = place_tasks(tasks, 2, variance_threshold=0.25)
    loads = _placement_loads(tasks, sigma, 2)
    cv = statistics.pstdev(loads) / statistics.mean(loads)
    assert cv <= 0.25, (sigma, loads)    # at HEAD cv stays ~0.47
    assert sigma[2] == 0                 # the big task itself never moved
    assert sigma[0] == sigma[1] == 1     # the two small head tasks migrated


def test_place_tasks_rebalance_considers_other_source_workers():
    """When the most-loaded worker's queue holds only an unmovable task,
    the next-most-loaded worker's queue must still be scanned instead of
    terminating the pass."""
    tasks = ([TaskSpec(home=0, cost=6.0)]          # w0: single huge task
             + [TaskSpec(home=1, cost=1.0) for _ in range(4)])  # w1: smalls
    sigma = place_tasks(tasks, 3, variance_threshold=0.25)
    # something from w1 must have reached the idle worker 2
    assert any(s == 2 for s in sigma[1:])
    loads = _placement_loads(tasks, sigma, 3)
    naive = _placement_loads(tasks, [t.home for t in tasks], 3)
    assert statistics.pstdev(loads) < statistics.pstdev(naive)


def test_pool_executes_everything():
    done = []
    lock = threading.Lock()

    def work(i):
        with lock:
            done.append(i)

    pool = WorkStealingPool(3, steal=True)
    for i in range(30):
        pool.submit(TaskSpec(fn=work, args=(i,), home=i % 3, cost=0.001))
    stats = pool.run()
    assert sorted(done) == list(range(30))
    assert stats["tasks"] == 30


def test_pool_steals_under_imbalance():
    evt = []

    def slow():
        time.sleep(0.02)

    pool = WorkStealingPool(4, steal=True,
                            cost_model=CostModel(steal_overhead_s=0.0))
    for _ in range(12):
        pool.submit(TaskSpec(fn=slow, home=0, cost=0.02, data_bytes=0))
    stats = pool.run()
    assert stats["tasks"] == 12
    assert stats["steals"] > 0


def _reference_try_get(deques, steal, cm, w):
    """Brute-force O(workers x queue) replica of the pre-fix _try_get."""
    if deques[w]:
        return deques[w].popleft(), False
    if not steal:
        return None
    victim, best_load = -1, 0.0
    for v in range(len(deques)):
        if v == w or not deques[v]:
            continue
        load = sum(t.cost for t in deques[v])
        if load > best_load:
            victim, best_load = v, load
    if victim < 0:
        return None
    t = deques[victim][-1]
    if best_load / 2.0 <= cm.steal_cost(t):
        return None
    deques[victim].pop()
    return t, True


def test_pool_running_totals_match_reference_steals():
    """The O(workers) victim selection (running per-deque cost totals) must
    make byte-identical decisions to the old O(workers x queue) scan: same
    victims, same Eq. 6 gates, same steal count — driven single-threaded on
    the imbalanced Table II workload so the comparison is deterministic."""
    import collections
    import copy
    cm = CostModel(steal_overhead_s=0.0)
    tasks = imbalanced_tasks()
    pool = WorkStealingPool(6, steal=True, cost_model=cm)
    ref = [collections.deque() for _ in range(6)]
    for t in tasks:
        pool.submit(t)
        ref[t.home].append(copy.copy(t))
        # invariant: incremental totals == recomputed queue sums
        for v in range(6):
            assert pool.queue_costs()[v] == pytest.approx(
                sum(q.cost for q in pool.deques[v]))
    steals = ref_steals = 0
    # Light workers drain their own queues then keep polling — the steal
    # path — before the heavy owners (0, 1) ever get scheduled.
    order = ([2] * 10 + [3] * 10 + [4] * 10 + [5] * 10
             + [0] * 10 + [1] * 10)
    for w in order:
        got = pool._try_get(w)
        ref_got = _reference_try_get(ref, True, cm, w)
        assert (got is None) == (ref_got is None)
        if got is None:
            continue
        task, stolen = got
        ref_task, ref_stolen = ref_got
        assert stolen == ref_stolen
        assert task.cost == ref_task.cost and task.home == ref_task.home
        steals += int(stolen)
        ref_steals += int(ref_stolen)
        for v in range(6):
            assert pool.queue_costs()[v] == pytest.approx(
                sum(q.cost for q in pool.deques[v]))
    assert steals == ref_steals
    assert steals > 0                       # the workload does steal
    assert all(not d for d in pool.deques)  # drained
    assert pool.queue_costs() == pytest.approx([0.0] * 6)


def test_phase_time_eq7():
    assert phase_time(2.0, 1.0, 10, 0.01, rho=1.0) == 2.0
    assert phase_time(1.0, 2.0, 10, 0.01, rho=0.0) == pytest.approx(2.1)


# ---------------------------------------------------------------------------
# Chunk-schedule policy engine
# ---------------------------------------------------------------------------

def test_hop_phase_time_limits():
    # k=1, no overlap floor: bulk-synchronous sum plus one tau round
    assert hop_phase_time(2.0, 1.0, 0.1, 1, tau_s=0.01) == \
        pytest.approx(2.0 + 1.1 + 0.01)
    # deep chunking on a comp-bound hop approaches max() + alpha residue
    t8 = hop_phase_time(2.0, 1.0, 0.0, 8)
    assert t8 == pytest.approx(2.0 + 1.0 / 8)
    # alpha cost grows with k: an alpha-dominated hop prefers k=1
    assert hop_phase_time(0.1, 0.1, 1.0, 4) > hop_phase_time(0.1, 0.1, 1.0, 1)


def test_choose_chunk_schedule_is_per_hop():
    """The policy engine's point: a comp-bound hop (chunking hides its
    comm) and an alpha-bound hop (chunking only adds latency) get
    *different* counts in one schedule."""
    comp_bound = (1e-3, 5e-4, 1e-7)     # t_comp >> alpha: chunk deep
    alpha_bound = (1e-6, 1e-6, 1e-3)    # alpha dominates: stay bulk
    sched = choose_chunk_schedule([comp_bound, alpha_bound],
                                  [[1, 2, 4, 8], [1, 2, 4, 8]])
    assert sched[0] > 1
    assert sched[1] == 1
    assert len(set(sched)) > 1


def test_choose_chunk_schedule_respects_feasibility():
    comp_bound = (1e-3, 5e-4, 1e-7)
    sched = choose_chunk_schedule([comp_bound, comp_bound],
                                  [[1, 2], [1, 2, 4, 8]])
    assert sched[0] <= 2                # clamped to the hop's own counts
    assert sched == (2, 8)              # both comp-bound: deepest feasible
    assert choose_chunk_schedule([], []) == ()


def test_choose_chunk_schedule_tie_prefers_smaller():
    """Zero-cost hops: every k ties, the bulk path must win."""
    sched = choose_chunk_schedule([(0.0, 0.0, 0.0)], [[1, 2, 4]],
                                  cost_model=CostModel(latency_s=0.0,
                                                       steal_overhead_s=0.0))
    assert sched == (1,)


def test_plan_cache_hit_miss():
    cache = PlanCache()
    key = plan_key(kind=("fft",), grid=(8, 8, 8), dtype="complex64",
                   decomp="pencil", mesh_shape=(2, 2),
                   mesh_axes=("data", "model"), backend="xla", n_chunks=1,
                   inverse=False)
    builds = []
    e1 = cache.get_or_create(key, lambda: builds.append(1) or "exe")
    e2 = cache.get_or_create(key, lambda: builds.append(1) or "exe")
    assert e1 is e2
    assert len(builds) == 1
    assert cache.stats()["hits"] == 1 and cache.stats()["misses"] == 1


def test_plan_cache_threadsafe():
    cache = PlanCache()
    key = ("k",)
    results = []

    def get():
        results.append(cache.get_or_create(key, lambda: object()).executable)

    threads = [threading.Thread(target=get) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len({id(r) for r in results}) == 1  # single winning build
