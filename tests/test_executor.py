"""Plan-stream executor: segmented pipelines, interleaving, donation.

Covers the executor redesign's acceptance criteria: stage segments chained
together are **bitwise identical** to the fused monolithic pipeline (across
{pencil, slab, hybrid} x {forward, inverse} x heterogeneous chunk
schedules), a mixed heterogeneous queue (batched 2-D plans + 3-D plans)
returns every entry bitwise equal to its solo execution in every dispatch
mode, donation never crosses entry boundaries (and is refused outright for
shared wrapper-memoized plans), and the scheduling layer (perf-model
pricing, Alg. 3 placement, greedy comm/comp merge, simulator validation,
watchdog straggler attribution) behaves deterministically.

Mesh-dependent paths run in subprocesses on a fake 8-device (2x4) mesh
(see tests/README.md); policy/introspection checks run in-process on the
session's single CPU device.
"""
import numpy as np
import pytest

from conftest import run_subprocess

COMMON = """
import warnings, numpy as np, jax, jax.numpy as jnp
from repro.compat import AxisType, make_mesh
mesh = make_mesh((2, 4), ("data", "model"), axis_types=(AxisType.Auto,)*2)
from repro.core import PlanStreamExecutor, execute_many, plan_fft
rng = np.random.default_rng(0)
def cx(shape):
    return (rng.standard_normal(shape)
            + 1j*rng.standard_normal(shape)).astype(np.complex64)
"""


# ---------------------------------------------------------------------------
# In-process: argument validation, pricing, policy, reporting
# ---------------------------------------------------------------------------

def _mini_queue(cpu_mesh):
    """A tiny heterogeneous queue: one batched 2-D plan x2 + one 3-D plan."""
    import jax.numpy as jnp

    from repro.core import plan_fft
    rng = np.random.default_rng(0)

    def cx(shape):
        return jnp.asarray((rng.standard_normal(shape)
                            + 1j * rng.standard_normal(shape)
                            ).astype(np.complex64))
    p2d = plan_fft(cpu_mesh, (8, 8), batch_shape=(3,))
    p3d = plan_fft(cpu_mesh, (4, 4, 8))
    return [(p2d, cx((3, 8, 8))), (p2d, cx((3, 8, 8))), (p3d, cx((4, 4, 8)))]


def test_executor_rejects_bad_mode():
    from repro.core import PlanStreamExecutor
    with pytest.raises(ValueError, match="mode must be one of"):
        PlanStreamExecutor(mode="eager")


def test_submit_validates_operand_shape(cpu_mesh):
    import jax.numpy as jnp

    from repro.core import PlanStreamExecutor, plan_fft
    ex = PlanStreamExecutor()
    plan = plan_fft(cpu_mesh, (8, 8), precompiled=False)
    with pytest.raises(ValueError, match="operand shape"):
        ex.submit(plan, jnp.zeros((4, 4), jnp.complex64))


def test_submit_refuses_donating_into_shared_plan(cpu_mesh):
    """Donation safety: a shared (wrapper-memoized) plan's input buffer may
    be owned by other callers — the executor must refuse, not donate."""
    import jax.numpy as jnp

    from repro.core import PlanStreamExecutor, plan_fft
    plan = plan_fft(cpu_mesh, (8, 8), precompiled=False)
    plan.shared = True
    ex = PlanStreamExecutor()
    with pytest.raises(ValueError, match="shared"):
        ex.submit(plan, jnp.zeros((8, 8), jnp.complex64), donate=True)
    assert len(ex) == 0  # nothing enqueued by the failed submit


def test_segment_pricing_and_dispatch_order(cpu_mesh):
    """Every entry decomposes into n_stages priced segments; the greedy
    merge preserves per-entry segment order, dispatches every segment
    exactly once, and opens with a compute segment."""
    from repro.core import PlanStreamExecutor, n_segments
    entries = _mini_queue(cpu_mesh)
    ex = PlanStreamExecutor(n_streams=2)
    for plan, x in entries:
        ex.submit(plan, x)
    order = ex._plan_schedule()

    expect = {i: n_segments(plan.pipeline_spec())
              for i, (plan, _) in enumerate(entries)}
    seen = [(s.entry, s.index) for s in order]
    assert sorted(seen) == [(i, j) for i in expect for j in range(expect[i])]
    assert order[0].kind == "comp"          # segment 0 is a local transform
    heads = {}
    for s in order:
        assert s.index == heads.get(s.entry, 0), \
            "per-entry segment order violated"
        heads[s.entry] = s.index + 1
        assert s.kind in ("comp", "comm")
        assert s.cost_s > 0.0
        assert s.bytes_out > 0
    streams = {s.stream for s in order}
    assert streams <= set(range(2))


def test_run_reports_simulator_validation(cpu_mesh):
    """run() validates the chosen interleaving with ScheduleSimulator:
    predicted wall <= serial sum, a full event trace, one event per
    segment."""
    import jax

    from repro.core import PlanStreamExecutor
    entries = _mini_queue(cpu_mesh)
    ex = PlanStreamExecutor(n_streams=2)
    for plan, x in entries:
        ex.submit(plan, x)
    outs = ex.run()
    jax.block_until_ready(outs)
    rep = ex.report()
    pred = rep["predicted"]
    assert pred["wall_s"] > 0.0
    assert pred["wall_s"] <= pred["serial_s"] * (1 + 1e-9)
    assert 0.0 < pred["overlap_efficiency"] <= 1.0 + 1e-9
    assert len(pred["events"]) == len(ex.last_schedule)
    assert len(ex) == 0                     # queue cleared by run()
    # A fresh queue reuses the executor object.
    for plan, x in entries:
        ex.submit(plan, x)
    jax.block_until_ready(ex.run())


def test_profile_mode_records_segment_times(cpu_mesh):
    import jax

    from repro.core import PlanStreamExecutor
    entries = _mini_queue(cpu_mesh)
    ex = PlanStreamExecutor(profile=True)
    for plan, x in entries:
        ex.submit(plan, x)
    jax.block_until_ready(ex.run())
    rep = ex.report()
    assert "measured" in rep
    times = rep["segment_times"]
    assert set(times) == {s.tag for s in ex.last_schedule}
    assert all(t > 0.0 for t in times.values())
    assert rep["measured"]["wall_s"] > 0.0


def test_watchdog_times_segments_and_maps_stragglers(cpu_mesh):
    """watchdog= implies timed dispatch: every segment is fed to the
    StepWatchdog, and flagged steps map back to segment tags."""
    import jax

    from repro.core import PlanStreamExecutor
    from repro.distributed.fault import StepWatchdog
    wd = StepWatchdog(tolerance=2.0)
    entries = _mini_queue(cpu_mesh)
    ex = PlanStreamExecutor(watchdog=wd)
    for plan, x in entries:
        ex.submit(plan, x)
    jax.block_until_ready(ex.run())
    assert len(wd.durations) == len(ex.last_schedule)
    assert "measured" in ex.report()
    # Straggler attribution is deterministic given the watchdog's flags:
    # inject a flag for step 0 and check it resolves to that segment's tag.
    wd.flagged.append((0, 1.23))
    tags = dict(ex.stragglers)
    assert tags.get(ex.last_schedule[0].tag) == 1.23


def test_stragglers_empty_without_watchdog(cpu_mesh):
    from repro.core import PlanStreamExecutor
    assert PlanStreamExecutor().stragglers == []


def test_predict_entry_time_positive(cpu_mesh):
    from repro.core import PlanStreamExecutor, plan_fft
    plan = plan_fft(cpu_mesh, (4, 4, 8), precompiled=False)
    ex = PlanStreamExecutor()
    assert ex.predict_entry_time(plan) > 0.0
    assert ex.predict_entry_time(plan, inverse=True) > 0.0


def test_execute_many_single_device_parity(cpu_mesh):
    """execute_many == solo plan calls, bitwise, on the 1-device mesh."""
    import jax

    from repro.core import execute_many
    entries = _mini_queue(cpu_mesh)
    solo = [np.asarray(jax.block_until_ready(plan(x)))
            for plan, x in entries]
    outs = execute_many(entries)
    jax.block_until_ready(outs)
    for got, want in zip(outs, solo):
        assert np.array_equal(np.asarray(got), want)


def test_plan_submit_and_execute_many_methods(cpu_mesh):
    """The plan-level API surface: ``plan.submit`` enqueues into a caller's
    executor; ``plan.execute_many`` runs a same-plan batch list."""
    import jax

    from repro.core import PlanStreamExecutor
    (p2d, xa), (_, xb), (p3d, y3) = _mini_queue(cpu_mesh)
    ex = PlanStreamExecutor()
    assert p2d.submit(xa, executor=ex) == 0
    assert p2d.submit(xb, executor=ex) == 1
    assert p3d.submit(y3, executor=ex) == 2
    outs = ex.run()
    jax.block_until_ready(outs)
    for plan, x, got in [(p2d, xa, outs[0]), (p2d, xb, outs[1]),
                         (p3d, y3, outs[2])]:
        assert np.array_equal(np.asarray(got),
                              np.asarray(jax.block_until_ready(plan(x))))
    many = p2d.execute_many([xa, xb])
    jax.block_until_ready(many)
    assert np.array_equal(np.asarray(many[0]), np.asarray(outs[0]))
    assert np.array_equal(np.asarray(many[1]), np.asarray(outs[1]))


# ---------------------------------------------------------------------------
# Subprocess (fake 8-device 2x4 mesh): parity sweeps, mixed queue, donation
# ---------------------------------------------------------------------------

def test_segment_parity_sweep_multidevice():
    """Chained stage segments are bitwise identical to the fused monolithic
    pipeline across {pencil, slab, hybrid} x {fwd, inv} x heterogeneous
    chunk schedules (incl. rfft and a 4-D hybrid grid)."""
    code = COMMON + """
warnings.simplefilter("ignore", RuntimeWarning)  # inverse-slab bulk fallback
CASES = [
    ("pencil_het", dict(grid=(8, 8, 16), decomp="pencil", n_chunks=(4, 2))),
    ("pencil_rfft", dict(grid=(8, 8, 16), decomp="pencil",
                         kinds=("rfft", "fft", "fft"))),
    ("slab_chunked", dict(grid=(8, 8, 16), decomp="slab", n_chunks=2)),
    ("hybrid4d_het", dict(grid=(4, 4, 8, 8), decomp="hybrid",
                          dim_groups=((0, 1), (2,), (3,)),
                          n_chunks=(2, 4))),
]
def chain(plan, v, inverse):
    segs = plan.segments(inverse=inverse, donate_intermediates=False)
    struct = plan.inv_in_struct if inverse else plan.in_struct
    v = jax.device_put(v, struct.sharding)
    for s in segs:
        v = s(v)
    return v
for name, kw in CASES:
    grid = kw.pop("grid")
    plan = plan_fft(mesh, grid, **kw)
    structs = plan.segment_boundary_structs()
    assert structs[0].shape == plan.in_struct.shape, name
    assert structs[-1].shape == plan.out_struct.shape, name
    x = jnp.asarray(rng.standard_normal(plan.in_struct.shape).astype(
        np.float32)) if str(plan.in_struct.dtype).startswith("float") \
        else jnp.asarray(cx(plan.in_struct.shape))
    y_mono = jax.block_until_ready(plan(x))
    y_seg = jax.block_until_ready(chain(plan, x, inverse=False))
    print(name, "fwd_bitwise",
          int(np.array_equal(np.asarray(y_mono), np.asarray(y_seg))))
    z_mono = jax.block_until_ready(plan.inverse(y_mono))
    z_seg = jax.block_until_ready(chain(plan, y_mono, inverse=True))
    print(name, "inv_bitwise",
          int(np.array_equal(np.asarray(z_mono), np.asarray(z_seg))))
"""
    out = run_subprocess(code)
    lines = [ln.split() for ln in out.strip().splitlines()]
    assert len(lines) == 8, out
    for name, direction, ok in lines:
        assert ok == "1", f"{name} {direction} diverged from monolithic:\n{out}"


def test_mixed_queue_parity_all_modes_multidevice():
    """A heterogeneous 4-entry queue (2x batched 2-D, one 3-D forward, one
    3-D inverse) returns every entry bitwise equal to its solo execution,
    in every dispatch mode."""
    code = COMMON + """
p2d = plan_fft(mesh, (8, 8), batch_shape=(4,))
p3d = plan_fft(mesh, (8, 8, 16), n_chunks=(4, 2))
xa, xb = jnp.asarray(cx((4, 8, 8))), jnp.asarray(cx((4, 8, 8)))
y3 = jnp.asarray(cx((8, 8, 16)))
yk = jax.block_until_ready(p3d(y3))
entries = [(p2d, xa), (p2d, xb), (p3d, y3), (p3d, yk, dict(inverse=True))]
solo = [np.asarray(jax.block_until_ready(p3d.inverse(yk))) if o.get("inverse")
        else np.asarray(jax.block_until_ready(p(v)))
        for p, v, o in [(*e, {}) if len(e) == 2 else e for e in entries]]
from repro.distributed.fault import StepWatchdog
for mode, kw in [("async", {}), ("pool", {}),
                 ("timed", dict(watchdog=StepWatchdog()))]:
    outs = execute_many(entries, mode=mode, **kw)
    jax.block_until_ready(outs)
    ok = all(np.array_equal(np.asarray(g), w) for g, w in zip(outs, solo))
    print(mode, int(ok))
"""
    out = run_subprocess(code)
    got = dict(ln.split() for ln in out.strip().splitlines())
    assert got == {"async": "1", "pool": "1", "timed": "1"}, out


def test_donation_across_entries_multidevice():
    """Donation never crosses entry boundaries: a donated entry's input is
    consumed, its neighbours' inputs stay live and valid, and every output
    is still bitwise equal to solo execution."""
    code = COMMON + """
p = plan_fft(mesh, (8, 8, 16))
xs = [jax.device_put(jnp.asarray(cx((8, 8, 16))), p.in_sharding)
      for _ in range(3)]
solo = [np.asarray(jax.block_until_ready(p(x))) for x in xs]
snap = [np.asarray(x) for x in xs]
ex = PlanStreamExecutor()
ex.submit(p, xs[0], sharded_in=True)
ex.submit(p, xs[1], sharded_in=True, donate=True)
ex.submit(p, xs[2], sharded_in=True)
outs = ex.run()
jax.block_until_ready(outs)
print("donated_deleted", int(xs[1].is_deleted()))
print("neighbours_live", int(not xs[0].is_deleted()
                             and not xs[2].is_deleted()))
print("neighbours_intact", int(np.array_equal(np.asarray(xs[0]), snap[0])
                               and np.array_equal(np.asarray(xs[2]), snap[2])))
print("outputs_bitwise", int(all(
    np.array_equal(np.asarray(g), w) for g, w in zip(outs, solo))))
"""
    out = run_subprocess(code)
    got = dict(ln.split() for ln in out.strip().splitlines())
    assert got == {"donated_deleted": "1", "neighbours_live": "1",
                   "neighbours_intact": "1", "outputs_bitwise": "1"}, out
