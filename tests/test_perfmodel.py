"""LogP perf model consistency: the analytic transpose volume must match
the all-to-all bytes in the compiled pipeline's HLO."""
import math

import pytest

from conftest import run_subprocess
from repro.core.decomp import local_shape, pencil, slab
from repro.core.perfmodel import (CPU_CORE, TPU_V5E, fft_total_flops,
                                  predict_fft_time)
from repro.core.redistribute import transpose_cost_bytes


def test_fft_flops_formula():
    n = 64
    got = fft_total_flops((n, n, n))
    assert got == pytest.approx(5 * n ** 3 * 3 * math.log2(n), rel=1e-6)


def test_predict_monotone_in_ranks():
    grid = (256, 256, 256)
    t4 = predict_fft_time(grid, pencil("a", "b"), {"a": 2, "b": 2}, TPU_V5E)
    t16 = predict_fft_time(grid, pencil("a", "b"), {"a": 4, "b": 4}, TPU_V5E)
    assert t16["t_comp_s"] < t4["t_comp_s"]


def test_overlap_bounds():
    import dataclasses
    grid = (128, 128, 128)
    m0 = dataclasses.replace(CPU_CORE, overlap=0.0)
    m1 = dataclasses.replace(CPU_CORE, overlap=1.0)
    t0 = predict_fft_time(grid, slab("a"), {"a": 4}, m0)
    t1 = predict_fft_time(grid, slab("a"), {"a": 4}, m1)
    # Eq. 2: perfect overlap = max(), bulk = sum
    assert t1["t_total_s"] == pytest.approx(
        max(t0["t_comp_s"], t0["t_comm_s"]), rel=1e-6)
    assert t0["t_total_s"] == pytest.approx(
        t0["t_comp_s"] + t0["t_comm_s"], rel=1e-6)


def test_transpose_bytes_match_hlo():
    """Analytic per-rank transpose volume == HLO all-to-all operand bytes."""
    out = run_subprocess("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.compat import AxisType, make_mesh
mesh = make_mesh((2, 4), ("data", "model"), axis_types=(AxisType.Auto,)*2)
from repro.core import make_decomposition, make_spec, build_pipeline
from repro.distributed.roofline import parse_hlo_collectives
dec = make_decomposition("pencil", ("data", "model"))
spec = make_spec(mesh, (16, 16, 16), dec, ("fft",)*3)
arg = jax.ShapeDtypeStruct((16, 16, 16), jnp.complex64,
                           sharding=NamedSharding(mesh, spec.in_spec()))
with mesh:
    co = jax.jit(build_pipeline(mesh, spec)).lower(arg).compile()
colls, per_kind = parse_hlo_collectives(co.as_text(), 8)
print("a2a_ops", len([c for c in colls if c.kind == "all-to-all"]))
print("a2a_bytes", per_kind.get("all-to-all", 0))
""", devices=8)
    vals = dict(l.split() for l in out.strip().splitlines())
    assert int(vals["a2a_ops"]) == 2          # one per stage boundary
    # analytic: per-device block c64(8B); redist1 over "data"(2),
    # redist2 over "model"(4)
    d1 = local_shape(pencil("data", "model").stages[0], (16, 16, 16),
                     {"data": 2, "model": 4})
    d2 = local_shape(pencil("data", "model").stages[1], (16, 16, 16),
                     {"data": 2, "model": 4})
    v1 = transpose_cost_bytes(d1, 8, 2)       # wire bytes (off-device part)
    v2 = transpose_cost_bytes(d2, 8, 4)
    # HLO operand bytes count the full shuffled block (incl. the diagonal
    # kept locally): full = wire * n/(n-1)
    full = v1 * 2 / 1 + v2 * 4 / 3
    got = float(vals["a2a_bytes"])
    assert got == pytest.approx(full, rel=0.35), (got, full)
