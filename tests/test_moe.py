"""MoE dispatch equivalence: grouped (sharded) vs global (baseline) must
agree when capacity is not binding; capacity drops degrade gracefully."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.distributed.sharding import MeshRules, ParamBuilder
from repro.models import moe as moe_lib


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("olmoe_1b_7b")
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # dropless regime
    rules = MeshRules()
    b = ParamBuilder(jax.random.key(0), rules)
    params = moe_lib.init_moe(b, "moe", cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                          jnp.float32)
    return cfg, rules, params, x


def test_grouped_matches_global(setup):
    cfg, rules, params, x = setup
    out_g, aux_g = moe_lib.moe_ffn_grouped(params, cfg, rules, x)
    out_n, aux_n = moe_lib.moe_ffn_global(params, cfg, rules, x)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_n),
                               rtol=2e-4, atol=2e-4)
    assert float(aux_g) == pytest.approx(float(aux_n), rel=1e-5)


def test_capacity_drop_partial_output(setup):
    cfg, rules, params, x = setup
    tight = dataclasses.replace(cfg, capacity_factor=0.25)
    out_t, _ = moe_lib.moe_ffn_grouped(params, tight, rules, x)
    out_f, _ = moe_lib.moe_ffn_grouped(params, cfg, rules, x)
    # dropped tokens produce zero contribution, not garbage
    assert bool(jnp.all(jnp.isfinite(out_t)))
    assert float(jnp.max(jnp.abs(out_t))) <= float(
        jnp.max(jnp.abs(out_f))) * 1.5


def test_router_aux_loss_balanced_uniform(setup):
    cfg, rules, params, x = setup
    # uniform router -> aux loss ~= 1.0 (E * E * (1/E) * (1/E))
    zero_router = dict(params, router=jnp.zeros_like(params["router"]))
    _, aux = moe_lib.moe_ffn_grouped(zero_router, cfg, rules, x)
    assert float(aux) == pytest.approx(1.0, rel=0.3)


def test_grad_flows_through_dispatch(setup):
    cfg, rules, params, x = setup

    def loss(p, xx):
        out, aux = moe_lib.moe_ffn_grouped(p, cfg, rules, xx)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(params, x)
    norms = {k: float(jnp.linalg.norm(v.astype(jnp.float32)))
             for k, v in g.items()}
    assert all(np.isfinite(v) for v in norms.values())
    assert norms["w_gate"] > 0 and norms["router"] > 0
