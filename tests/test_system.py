"""End-to-end behaviour tests: training converges, checkpoint/restart is
bit-consistent with an uninterrupted run, serve loop generates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import train


def test_training_loss_decreases(cpu_mesh, tmp_path):
    out = train("stablelm_1_6b", steps=30, seq_len=16, global_batch=2,
                smoke=True, mesh=cpu_mesh, log_every=100)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.1, f"no learning: {first} -> {last}"


def test_checkpoint_restart_matches_uninterrupted(cpu_mesh, tmp_path):
    """Train 20 steps straight vs 10 + crash + resume 10: same final loss."""
    d1 = str(tmp_path / "a")
    out_full = train("stablelm_1_6b", steps=20, seq_len=16, global_batch=2,
                     smoke=True, mesh=cpu_mesh, ckpt_dir=d1, ckpt_every=10,
                     log_every=100)

    d2 = str(tmp_path / "b")
    train("stablelm_1_6b", steps=20, seq_len=16, global_batch=2, smoke=True,
          mesh=cpu_mesh, ckpt_dir=d2, ckpt_every=10, log_every=100,
          stop_after=10)                       # simulated preemption
    out_resumed = train("stablelm_1_6b", steps=20, seq_len=16,
                        global_batch=2, smoke=True, mesh=cpu_mesh,
                        ckpt_dir=d2, ckpt_every=10, log_every=100)
    assert out_resumed["final_loss"] == pytest.approx(
        out_full["final_loss"], rel=1e-3)


def test_generation_loop(cpu_mesh, rules):
    """Prefill + N decode steps produce a coherent growing sequence."""
    from repro.configs import smoke_config
    from repro.launch.steps import (build_params, make_decode_step,
                                    make_prefill_step)
    from repro.models.transformer import pad_caches
    cfg = smoke_config("qwen3_8b")
    with cpu_mesh:
        params, _ = build_params(cfg, rules, abstract=False)
        toks = jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab, (1, 8)), jnp.int32)
        prefill = jax.jit(make_prefill_step(cfg, rules))
        decode = jax.jit(make_decode_step(cfg, rules))
        logits, caches = prefill(params, {"tokens": toks})
        # caches from prefill are prompt-sized; pad to the decode budget
        caches = pad_caches(caches, cfg, max_seq=16)
        cur = jnp.argmax(logits, axis=-1)[:, None]
        outs = []
        for i in range(4):
            nxt, logits_d, caches = decode(params, caches, cur,
                                           jnp.asarray(8 + i, jnp.int32))
            assert bool(jnp.all(jnp.isfinite(logits_d.astype(jnp.float32))))
            cur = nxt[:, None].astype(jnp.int32)
            outs.append(int(nxt[0]))
        assert all(0 <= t < cfg.padded_vocab for t in outs)
