"""Shared fixtures.  NOTE: no XLA_FLAGS device-count forcing here — smoke
tests and benches must see the real single CPU device; multi-device tests
spawn subprocesses that set the flag themselves."""
import os
import sys

import jax
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def cpu_mesh():
    # jax 0.4.x has neither jax.sharding.AxisType nor the axis_types kwarg;
    # repro.compat.make_mesh papers over both.
    from repro.compat import AxisType, make_mesh
    return make_mesh((1, 1), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)


@pytest.fixture(scope="session")
def rules(cpu_mesh):
    from repro.distributed.sharding import MeshRules
    return MeshRules.for_mesh(cpu_mesh)


def run_subprocess(code: str, devices: int = 8, timeout: int = 600) -> str:
    """Run ``code`` in a fresh python with N fake XLA host devices."""
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert res.returncode == 0, f"subprocess failed:\n{res.stdout}\n{res.stderr}"
    return res.stdout
